"""The online serving session: admission → batched fused lookup → Θ control.

This module closes the paper's SLO loop (§Abstract, §I, §VI.D) end to end.
Where ``launch/serve.py`` used to run the cluster first and *replay* its
metrics through the batching simulator afterwards, :class:`ServingSession`
is event-driven and online:

1. **Arrivals** — an open-loop :class:`~repro.data.scenarios.RequestStream`
   (Poisson or bursty arrivals × any stream process, so a ``Drift`` workload
   rotates its hot set across serving windows) lands requests tick by tick,
   each stamped with an absolute deadline ``arrival + slo_ticks``.
2. **Admission** — the :class:`~repro.serving.scheduler.EDFScheduler` fills
   free batch slots earliest-deadline-first and sheds requests that cannot
   meet their deadline even if started immediately (at the *estimated* cost
   derived from the server's profiled first-hit CDF R).
3. **Classification** — each tick's newly admitted requests are batched and
   classified through the real fused lookup path:
   :func:`~repro.core.semantic_cache.lookup_all_layers` on the **live**
   serving table cut by :meth:`CocaCluster.serving_table
   <repro.core.engine.CocaCluster.serving_table>` — not oracle exit layers.
   The lookup's verdict (first hitting tap, or a full-depth miss) *resolves*
   the slot's true block count; early exits retire slots early and the next
   queued request refills them — continuous batching as the execution
   engine, with the same block-tick accounting as
   :mod:`repro.serving.batching` (which is exactly what makes the session
   replay-parity-testable against ``simulate``).
4. **Control** — at every window boundary the window's
   :class:`~repro.serving.scheduler.SLOStats` drive the
   :class:`~repro.serving.scheduler.ThetaController` (attainment below
   target lowers Θ for more early exits; slack raises it for accuracy) via
   ``cluster.set_theta``, **and** the observed request recency τ feeds
   between-window ACA re-allocation via ``cluster.serving_table`` — the
   cache adapts online exactly as §VI.D's Θ-per-SLO table prescribes,
   but continuously.

Latency accounting: scheduler latencies are in raw block-ticks
(queue wait + execution); the per-tap lookup overhead is applied to the
session's busy ticks exactly as ``simulate`` applies it
(``ticks * (1 + lookup_tick_fraction)``), so live and replay numbers are
directly comparable.  Idle ticks (open-loop lulls) execute no block-batch
and are excluded from the compute bill.

Drivers: ``python -m repro.launch.serve`` (synthetic taps),
``examples/serve_stream.py`` (a real transformer backbone supplying the tap
vectors), ``benchmarks/table2_slo.py`` (the load sweep behind
``BENCH_serving.json``).
"""

from __future__ import annotations

import dataclasses
import heapq
from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.semantic_cache import CacheTable, lookup_all_layers
from repro.data.scenarios import RequestStream
from repro.serving.batching import BatchingConfig
from repro.serving.scheduler import (EDFScheduler, Request, SLOStats,
                                     ThetaController)

# TapFn: (window_index, labels (N,)) -> (sems (N, L, d), logits (N, C)).
# The session batches each tick's admitted requests into one call.
ServeTapFn = Callable[[int, np.ndarray], tuple]


@partial(jax.jit, static_argnames=("cfg",))
def _batched_lookup(table: CacheTable, sems: jax.Array, cfg):
    """The session's per-tick lookup, compiled once per (shape, Θ): ticks
    pad their admitted batch to ``max_slots`` rows so every tick re-hits
    the same trace (Θ changes retrace, but the controller quantises)."""
    return lookup_all_layers(table, sems, cfg)


@dataclasses.dataclass(frozen=True)
class ServeLoopConfig:
    """Knobs of one online serving session.

    ``slo_ticks`` is the per-request deadline in block-ticks (the paper's
    per-task deadline, §I); ``windows`` × ``window_ticks`` is the horizon.
    Θ control and re-allocation can be frozen independently — the
    ``frozen-Θ`` baseline of ``BENCH_serving.json`` is ``adapt_theta=False,
    reallocate=False``.
    """

    batching: BatchingConfig
    windows: int = 8                 # control windows
    window_ticks: int = 64           # block-ticks per window
    slo_ticks: float = 30.0          # deadline = arrival + slo_ticks
    target: float = 0.95             # attainment target for Θ control
    margin: float = 0.02             # controller hysteresis half-width
    theta_step: float = 0.1          # multiplicative Θ step
    theta_lo: float = 0.01
    theta_hi: float = 0.5
    adapt_theta: bool = True         # drive Θ from window attainment
    reallocate: bool = True          # between-window ACA re-allocation
    drain: bool = True               # finish the backlog after the horizon
    drain_max_ticks: int = 100_000

    def __post_init__(self):
        if self.windows < 1 or self.window_ticks < 1:
            raise ValueError("windows and window_ticks must be >= 1")
        if self.slo_ticks <= 0:
            raise ValueError("slo_ticks must be > 0")


class WindowReport(NamedTuple):
    """One control window as the session saw it."""

    window: int
    theta: float              # Θ in force *during* this window
    stats: SLOStats           # idle-window safe
    arrivals: int
    hits: int                 # cache-resolved among requests admitted
    admitted: int
    reallocated: bool
    degraded: bool = False    # served from a stale/absent table (sync fault)


class SessionResult(NamedTuple):
    """The live session's outcome — no metric replay involved.

    ``ticks`` is the lookup-adjusted busy-tick bill (block-batch executions
    actually run, idle ticks excluded); ``throughput`` is served requests
    per adjusted tick, the number load-level comparisons divide.
    ``exit_blocks`` holds every admitted request's resolved block count in
    admission order — feeding it to :func:`repro.serving.batching.simulate`
    reproduces the session's tick bill exactly on a backlogged trace (the
    parity test).
    """

    stats: SLOStats
    windows: list
    ticks: float
    served: int
    shed: int
    arrivals: int
    hit_ratio: float          # of admitted requests
    accuracy: float           # of served requests with known labels
    throughput: float
    theta_trace: list
    exit_blocks: np.ndarray


class ServingSession:
    """One client's online serving loop over a live CoCa cluster.

    ``cluster`` — a bootstrapped :class:`~repro.core.engine.CocaCluster`
    whose policy cuts the serving table (any ``AllocationPolicy``).
    ``workload`` — the open-loop request stream.  ``tap_fn(window, labels)``
    supplies the semantic taps and full-model logits for a batch of
    admitted requests — synthetic taps in the launcher, a real backbone's
    taps in ``examples/serve_stream.py``.  ``use_cache=False`` runs the
    same loop with the lookup disabled (every request pays all blocks) —
    the live no-cache baseline.

    Faults: with ``faults=`` (a :class:`repro.distributed.faults.FaultSpec`)
    every window-boundary table download runs through the spec's download
    matrix and outage windows, keyed by **window index** in place of the
    engine's round index.  ``hardened=True`` retries a failed transfer
    under ``retry``'s budget and otherwise serves the window from the last
    good table (staleness-counted, cache-off past ``stale_limit``) while
    the Θ controller **holds**
    (:meth:`~repro.serving.scheduler.ThetaController.hold`) — a
    fault-induced attainment dip says nothing about Θ.  ``hardened=False``
    is the naive contrast: one attempt, a dropped table serves full-depth,
    a corrupt/truncated one is used as delivered, and Θ reacts to the dip
    it caused.  An empty spec is discarded outright, so the zero-fault
    session is the pre-fault code path bit-for-bit.
    """

    def __init__(self, cluster, cfg: ServeLoopConfig,
                 workload: RequestStream | None, tap_fn: ServeTapFn, *,
                 use_cache: bool = True, client: int = 0,
                 faults=None, retry=None, hardened: bool = True,
                 stale_limit: int = 4):
        if (workload is not None
                and workload.num_classes != cluster.sim.cache.num_classes):
            raise ValueError(
                f"workload has {workload.num_classes} classes, cluster cache "
                f"has {cluster.sim.cache.num_classes}")
        self.cluster = cluster
        self.cfg = cfg
        self.workload = workload
        self.tap_fn = tap_fn
        self.use_cache = use_cache
        self.client = client
        self._faults = None
        if faults is not None and not faults.empty:
            from repro.distributed.faults import RetryPolicy
            self._faults = faults
            self.retry = retry if retry is not None else RetryPolicy()
        self.hardened = hardened
        self.stale_limit = stale_limit
        self._good_table = None      # last successfully synced table
        self._stale = 0              # windows since a good sync
        self._pad_block = None       # device pad rows, armed by start()
        I = cluster.sim.cache.num_classes
        # request-stream recency: tau_i = admitted requests since class i
        # was last observed (the engine's Eq.-10 unit, fed back at each
        # window boundary so ACA tracks the *served* distribution)
        self._last_seen = np.full(I, -1, np.int64)
        self._seen = 0

    # ----------------------------------------------------------------- utils
    def _estimated_blocks(self) -> float:
        """Cold-start admission cost estimate: expected blocks under the
        server's profiled first-hit CDF R (full depth without a cache).
        Once windows complete, the estimate tracks the *observed* resolved
        block counts instead (EWMA at each window boundary) — a static
        estimate goes stale the moment the Θ controller moves, and a stale
        underestimate admits doomed requests the shedding valve should have
        dropped."""
        nb = self.cfg.batching.num_blocks
        if not self.use_cache:
            return float(nb)
        r = np.asarray(self.cluster.r_est, float)
        first = np.diff(np.concatenate([[0.0], np.clip(r, 0.0, 1.0)]))
        first = np.clip(first, 0.0, None)
        blocks = np.arange(1, len(r) + 1, dtype=float)
        exp = float((first * blocks).sum() + (1.0 - min(r[-1], 1.0)) * nb)
        return float(np.clip(exp, 1.0, nb))

    def _observe(self, labels: np.ndarray) -> None:
        for lab in labels:
            self._last_seen[int(lab)] = self._seen
            self._seen += 1

    def _tau(self) -> np.ndarray:
        # never-requested classes are maximally stale (Eq. 10 scores LOW tau
        # as hot); at cold start (_seen == 0) this is all-zeros, matching
        # the engine's fresh-client convention
        tau = np.where(self._last_seen < 0, self._seen,
                       self._seen - 1 - self._last_seen)
        return tau.astype(np.int32)

    def _window_table(self, w: int):
        """The serving table for window ``w``, resolved through the fault
        spec (the identity when none is armed): ``(table, degraded)``.

        The serving loop's clock is block-ticks, so the retry budget is
        honoured in *wall seconds that never hit the tick bill* — the
        window boundary is between ticks; what the budget still decides is
        how many redraws a hardened client gets before giving up.
        """
        if not self.use_cache:
            return None, False

        def cut():
            return self.cluster.serving_table(
                client=self.client, tau=self._tau(), round_index=w)

        if self._faults is None:
            return cut(), False
        from repro.distributed.faults import (_DOM_CORRUPT_DOWN, _DOM_JITTER,
                                              corrupt_table, truncate_table)
        spec = self._faults
        down = spec.server_down(w)
        fault = "drop" if down else spec.draw_download(w, self.client)
        if fault == "ok":
            table = cut()
            self._good_table, self._stale = table, 0
            return table, False
        if self.hardened:
            jit_rng = spec.rng(_DOM_JITTER, w, self.client, 2)
            spent = 0.0
            for attempt in range(self.retry.max_retries):
                wait = self.retry.backoff(attempt, jit_rng)
                if spent + wait > self.retry.timeout:
                    break
                spent += wait
                redraw = ("drop" if down else
                          spec.draw_download(w, self.client,
                                             attempt=attempt + 1))
                if redraw == "ok":
                    table = cut()
                    self._good_table, self._stale = table, 0
                    return table, False
            self._stale += 1
            if (self._good_table is not None
                    and self._stale <= self.stale_limit):
                return self._good_table, True        # bounded-stale table
            return None, True                        # cache-off
        # naive: one attempt, serve whatever the wire delivered
        self._stale += 1
        if fault == "corrupt":
            return corrupt_table(
                cut(), spec.rng(_DOM_CORRUPT_DOWN, w, self.client)), True
        if fault == "partial":
            return truncate_table(cut(), spec.partial_frac), True
        return None, True                            # dropped download

    def _classify(self, window: int, labels: np.ndarray,
                  table: CacheTable | None):
        """The per-tick batched classification: real taps, real fused
        lookup on the live table.  Returns (blocks, hit, pred)."""
        nb = self.cfg.batching.num_blocks
        n = len(labels)
        sems, logits = self.tap_fn(window, labels)
        if not (self.use_cache and table is not None):
            # the no-cache tick's one bundled transfer (tap_fn may hand back
            # device arrays); explicit, so the transfer guard stays quiet
            logits = jax.device_get(logits)  # cocalint: disable=CL202
            model_pred = np.argmax(logits, axis=1).astype(np.int32)
            return (np.full(n, nb, np.int64), np.zeros(n, bool), model_pred)
        sems = jnp.asarray(sems)         # explicit h2d — guard-legal
        pad = self.cfg.batching.max_slots - n
        if pad > 0:                      # fixed shape -> one compiled trace
            # lax.slice_in_dim, not _pad_block[:pad]: eager jnp basic
            # indexing materialises its index scalars host-side (an
            # implicit transfer); the lax slice is fully static.
            sems = jnp.concatenate(
                [sems, jax.lax.slice_in_dim(self._pad_block, 0, pad)])
        look = _batched_lookup(table, sems, self.cluster.sim.cache)
        # The tick's ONE bundled device->host transfer: lookup verdicts and
        # model logits ride together (the serving-tick edition of PR 1's
        # one-device_get-per-round contract).
        # cocalint: disable=CL202
        hit, exit_layer, cache_pred, logits = jax.device_get(
            (look.hit, look.exit_layer, look.pred, logits))
        model_pred = np.argmax(logits, axis=1).astype(np.int32)
        hit = hit[:n]
        blocks = np.where(hit, np.minimum(exit_layer[:n] + 1, nb), nb)
        pred = np.where(hit, cache_pred[:n], model_pred)
        return blocks.astype(np.int64), hit, pred.astype(np.int32)

    # ----------------------------------------------- the replica-facing seam
    #
    # A gateway tier (repro.fleet.gateway.FleetGateway) drives N replica
    # sessions in lockstep through these methods instead of run():
    # start() → per window: begin_window / submit / tick / end_window →
    # report().  run() itself is written on the same seam, so a 1-replica
    # fleet that replays the same call sequence is bit-identical to a bare
    # session (the degenerate-case parity test in tests/test_fleet.py).

    def start(self) -> "ServingSession":
        """Arm the session's run state (scheduler, Θ controller, window-0
        table, admission estimate).  Idempotent per run; must precede any
        submit/tick call."""
        cfg = self.cfg
        self._sched = EDFScheduler(max_slots=cfg.batching.max_slots)
        self._ctl = ThetaController(
            theta=float(self.cluster.sim.cache.theta), target=cfg.target,
            margin=cfg.margin, step=cfg.theta_step,
            lo=cfg.theta_lo, hi=cfg.theta_hi)
        self._table, self._degraded_now = self._window_table(0)
        # Device-resident pad rows for the tick's fixed-shape lookup batch,
        # built once per run via an *explicit* device_put: padding a tick
        # with eager jnp.zeros would materialise a fresh host constant
        # every tick (an implicit transfer the sanitizer's guard forbids).
        cc = self.cluster.sim.cache
        self._pad_block = jax.device_put(
            np.zeros((cfg.batching.max_slots, cc.num_layers, cc.sem_dim),
                     np.float32))
        self._est_f = self._estimated_blocks()
        self._est = int(np.ceil(self._est_f))
        self._labels_by_rid: dict[int, int] = {}
        self._pred_by_rid: dict[int, int] = {}
        self._exit_blocks: list[int] = []
        self._reports: list[WindowReport] = []
        self._theta_trace: list[float] = []
        self._correct = self._served_labeled = 0
        self._next_rid = 0
        self._admitted_total = self._hits_total = self._arrivals_total = 0
        self._win0 = (0, 0, 0, 0)        # window-start counter snapshot
        return self

    @property
    def estimate(self) -> float:
        """The current (EWMA-tracked) expected block cost at admission."""
        return self._est_f

    def set_estimate(self, est_f: float) -> None:
        """Override the admission cost estimate — the fleet gateway lifts
        the EWMA to fleet level (one estimate from every replica's resolved
        blocks) and pushes it back down here each window."""
        self._est_f = float(est_f)
        self._est = int(np.ceil(self._est_f))

    def submit(self, label: int, *, arrival: float | None = None,
               deadline: float | None = None) -> Request:
        """Enqueue one request.  ``arrival``/``deadline`` default to the
        session clock and the configured SLO; a gateway re-dispatching a
        spilled request passes the originals so the deadline survives the
        hop.  Returns the stamped :class:`Request`."""
        sched = self._sched
        arrival = sched.tick if arrival is None else float(arrival)
        if deadline is None:
            deadline = arrival + self.cfg.slo_ticks
        req = Request(rid=self._next_rid, arrival=arrival,
                      blocks_needed=self._est, deadline=float(deadline))
        self._labels_by_rid[req.rid] = int(label)
        self._next_rid += 1
        self._arrivals_total += 1
        sched.submit(req)
        return req

    def tick(self, window: int) -> list[tuple[Request, float, bool]]:
        """One block-tick: EDF admission → batched live lookup resolves the
        admitted requests → advance.  Returns the retirements
        ``(request, latency, missed)``.  Safe on an idle (or evacuated)
        session — the clock still advances, which is what keeps a fleet's
        replicas tick-synchronised through an outage."""
        sched = self._sched
        placed = sched.admit()
        if placed:
            labs = np.asarray(
                [self._labels_by_rid[r.rid] for _, r in placed], np.int32)
            blocks, hit, pred = self._classify(window, labs, self._table)
            for (slot, req), b, h, p in zip(placed, blocks, hit, pred):
                sched.resolve(slot, int(b))
                self._pred_by_rid[req.rid] = int(p)
                self._exit_blocks.append(int(b))
            self._observe(labs)
            self._admitted_total += len(placed)
            self._hits_total += int(hit.sum())
        retired = sched.advance()
        for req, _lat, _missed in retired:
            lab = self._labels_by_rid[req.rid]
            self._served_labeled += 1
            self._correct += int(self._pred_by_rid[req.rid] == lab)
        return retired

    def begin_window(self, window: int) -> None:
        """Open control window ``window``: record the Θ in force and mark
        the scheduler's window-stat baseline."""
        self._theta_trace.append(float(self.cluster.sim.cache.theta))
        self._win0 = (self._admitted_total, self._hits_total,
                      len(self._exit_blocks), self._arrivals_total)
        self._sched.begin_window()

    def window_blocks(self) -> list[int]:
        """The block counts this window's lookups actually resolved — the
        fleet gateway pools these across replicas for the lifted estimate."""
        return self._exit_blocks[self._win0[2]:]

    def window_stats(self) -> SLOStats:
        return self._sched.window_stats()

    def refresh_estimate(self) -> None:
        """EWMA the admission estimate toward this window's resolved block
        counts (tracks the Θ controller)."""
        blocks = self.window_blocks()
        if blocks:
            self._est_f = 0.5 * self._est_f + 0.5 * float(np.mean(blocks))
            self._est = int(np.ceil(self._est_f))

    def end_window(self, window: int, *, control: bool = True,
                   reallocate: bool | None = None) -> WindowReport:
        """Close window ``window``: stats → (optionally) estimate refresh +
        Θ control → table re-allocation for the next window → report.

        ``control=False`` skips the session's own estimate/Θ updates — the
        gateway owns both at fleet level and pushes its verdicts through
        :meth:`set_estimate` / ``cluster.set_theta`` before calling this.
        ``reallocate`` overrides ``cfg.reallocate`` for this boundary (an
        outaged replica cannot download a fresh cut)."""
        cfg = self.cfg
        stats = self._sched.window_stats()
        realloc = False
        if control:
            # refresh the admission estimate from what this window's
            # lookups actually resolved (tracks the Θ controller)
            self.refresh_estimate()
            # close the loop: attainment -> Θ, observed recency -> ACA.
            # A degraded window's dip is a sync fault, not a Θ signal —
            # the hardened session holds AIMD instead of chasing it.
            if cfg.adapt_theta and stats.served + stats.shed > 0:
                if (self._degraded_now and self.hardened
                        and self._faults is not None):
                    self._ctl.hold()
                else:
                    self.cluster.set_theta(self._ctl.update(stats.attainment))
        was_degraded = self._degraded_now
        do_realloc = cfg.reallocate if reallocate is None else reallocate
        if do_realloc and self.use_cache:
            self._table, self._degraded_now = self._window_table(window + 1)
            realloc = not self._degraded_now
        report = WindowReport(
            window=window, theta=self._theta_trace[-1], stats=stats,
            arrivals=self._arrivals_total - self._win0[3],
            hits=self._hits_total - self._win0[1],
            admitted=self._admitted_total - self._win0[0],
            reallocated=realloc, degraded=was_degraded)
        self._reports.append(report)
        return report

    def resync(self, window: int) -> None:
        """Re-cut the serving table mid-horizon — a recovered fleet replica
        returning from an outage pulls a fresh allocation for ``window``."""
        if self.use_cache:
            self._table, self._degraded_now = self._window_table(window)

    def reset_recency(self) -> None:
        """Forget the observed request recency — a replica whose outage
        outlasted the churn stale limit rejoins cold (the fleet analogue of
        ``rejoin_client(fresh=True)``)."""
        self._last_seen = np.full(len(self._last_seen), -1, np.int64)
        self._seen = 0

    def evacuate(self) -> list[tuple[Request, int]]:
        """Pull every queued and in-flight request off this session — the
        outage spill: the gateway re-dispatches them to hash-ring neighbor
        replicas (partial block progress on in-flight slots is lost, which
        is exactly what a replica crash costs).  Returns ``(request,
        label)`` in deadline (EDF) order; the session is left idle but its
        clock and counters intact."""
        sched = self._sched
        out = []
        while sched.queue:
            _, _, req = heapq.heappop(sched.queue)
            out.append((req, self._labels_by_rid[req.rid]))
        for i, s in enumerate(sched.slots):
            if s is not None:
                req, _remaining, _start = s
                out.append((req, self._labels_by_rid[req.rid]))
                sched.slots[i] = None
        out.sort(key=lambda rl: (rl[0].deadline, rl[0].rid))
        return out

    def backlog(self) -> int:
        """Queued + in-flight requests — the gateway's load signal."""
        sched = self._sched
        return len(sched.queue) + sum(s is not None for s in sched.slots)

    @property
    def latencies(self) -> list[float]:
        """Per-request latencies retired so far (block-ticks) — the fleet
        aggregates these across replicas for fleet-level p50/p95."""
        return list(self._sched.latencies)

    def window_latencies(self) -> list[float]:
        """Latencies retired since :meth:`begin_window` (the slice behind
        :meth:`window_stats`'s percentiles)."""
        return list(self._sched.latencies[self._sched._mark[3]:])

    @property
    def hits(self) -> int:
        """Lookup hits so far (numerator of :attr:`SessionResult.hit_ratio`)."""
        return self._hits_total

    @property
    def admitted(self) -> int:
        """Requests admitted to a batch slot so far."""
        return self._admitted_total

    def drain_backlog(self, window: int | None = None) -> None:
        """Tick until the queue and slots are empty (bounded by
        ``cfg.drain_max_ticks``)."""
        cfg = self.cfg
        if window is None:
            window = cfg.windows - 1
        sched = self._sched
        t = 0
        while ((sched.queue or any(s is not None for s in sched.slots))
               and t < cfg.drain_max_ticks):
            self.tick(window)
            t += 1

    def report(self) -> SessionResult:
        """The session's outcome so far — the replica-facing counterpart of
        :meth:`run`'s return value."""
        sched = self._sched
        overhead = (1 + self.cfg.batching.lookup_tick_fraction
                    if self.use_cache else 1.0)
        ticks = sched.busy_ticks * overhead
        return SessionResult(
            stats=sched.stats(), windows=list(self._reports), ticks=ticks,
            served=sched.served, shed=sched.shed,
            arrivals=self._arrivals_total,
            hit_ratio=self._hits_total / max(self._admitted_total, 1),
            accuracy=self._correct / max(self._served_labeled, 1),
            throughput=sched.served / max(ticks, 1e-9),
            theta_trace=list(self._theta_trace),
            exit_blocks=np.asarray(self._exit_blocks, np.int64))

    # ------------------------------------------------------------------ run
    def run(self) -> SessionResult:
        """The classic closed loop, expressed on the seam."""
        if self.workload is None:
            raise RuntimeError("run() needs a workload; gateway-managed "
                               "sessions are driven through the seam "
                               "(start/submit/tick/end_window)")
        cfg = self.cfg
        self.start()
        for w in range(cfg.windows):
            self.begin_window(w)
            counts, labels = self.workload.window(w, cfg.window_ticks)
            offsets = np.concatenate([[0], np.cumsum(counts)])
            for t in range(cfg.window_ticks):
                for lab in labels[offsets[t]:offsets[t + 1]]:
                    self.submit(int(lab))
                self.tick(w)
            self.end_window(w)
        if cfg.drain:
            self.drain_backlog(cfg.windows - 1)
        return self.report()


def throughput_gain(cached: SessionResult, nocache: SessionResult) -> float:
    """Live throughput multiple: served-per-adjusted-tick ratio between a
    cached session and its no-cache twin on the same workload.  Idle-safe:
    two idle sessions gain exactly 1.0."""
    if cached.served == 0 and nocache.served == 0:
        return 1.0
    return cached.throughput / max(nocache.throughput, 1e-9)
