"""The online serving session: admission → batched fused lookup → Θ control.

This module closes the paper's SLO loop (§Abstract, §I, §VI.D) end to end.
Where ``launch/serve.py`` used to run the cluster first and *replay* its
metrics through the batching simulator afterwards, :class:`ServingSession`
is event-driven and online:

1. **Arrivals** — an open-loop :class:`~repro.data.scenarios.RequestStream`
   (Poisson or bursty arrivals × any stream process, so a ``Drift`` workload
   rotates its hot set across serving windows) lands requests tick by tick,
   each stamped with an absolute deadline ``arrival + slo_ticks``.
2. **Admission** — the :class:`~repro.serving.scheduler.EDFScheduler` fills
   free batch slots earliest-deadline-first and sheds requests that cannot
   meet their deadline even if started immediately (at the *estimated* cost
   derived from the server's profiled first-hit CDF R).
3. **Classification** — each tick's newly admitted requests are batched and
   classified through the real fused lookup path:
   :func:`~repro.core.semantic_cache.lookup_all_layers` on the **live**
   serving table cut by :meth:`CocaCluster.serving_table
   <repro.core.engine.CocaCluster.serving_table>` — not oracle exit layers.
   The lookup's verdict (first hitting tap, or a full-depth miss) *resolves*
   the slot's true block count; early exits retire slots early and the next
   queued request refills them — continuous batching as the execution
   engine, with the same block-tick accounting as
   :mod:`repro.serving.batching` (which is exactly what makes the session
   replay-parity-testable against ``simulate``).
4. **Control** — at every window boundary the window's
   :class:`~repro.serving.scheduler.SLOStats` drive the
   :class:`~repro.serving.scheduler.ThetaController` (attainment below
   target lowers Θ for more early exits; slack raises it for accuracy) via
   ``cluster.set_theta``, **and** the observed request recency τ feeds
   between-window ACA re-allocation via ``cluster.serving_table`` — the
   cache adapts online exactly as §VI.D's Θ-per-SLO table prescribes,
   but continuously.

Latency accounting: scheduler latencies are in raw block-ticks
(queue wait + execution); the per-tap lookup overhead is applied to the
session's busy ticks exactly as ``simulate`` applies it
(``ticks * (1 + lookup_tick_fraction)``), so live and replay numbers are
directly comparable.  Idle ticks (open-loop lulls) execute no block-batch
and are excluded from the compute bill.

Drivers: ``python -m repro.launch.serve`` (synthetic taps),
``examples/serve_stream.py`` (a real transformer backbone supplying the tap
vectors), ``benchmarks/table2_slo.py`` (the load sweep behind
``BENCH_serving.json``).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.semantic_cache import CacheTable, lookup_all_layers
from repro.data.scenarios import RequestStream
from repro.serving.batching import BatchingConfig
from repro.serving.scheduler import (EDFScheduler, Request, SLOStats,
                                     ThetaController)

# TapFn: (window_index, labels (N,)) -> (sems (N, L, d), logits (N, C)).
# The session batches each tick's admitted requests into one call.
ServeTapFn = Callable[[int, np.ndarray], tuple]


@partial(jax.jit, static_argnames=("cfg",))
def _batched_lookup(table: CacheTable, sems: jax.Array, cfg):
    """The session's per-tick lookup, compiled once per (shape, Θ): ticks
    pad their admitted batch to ``max_slots`` rows so every tick re-hits
    the same trace (Θ changes retrace, but the controller quantises)."""
    return lookup_all_layers(table, sems, cfg)


@dataclasses.dataclass(frozen=True)
class ServeLoopConfig:
    """Knobs of one online serving session.

    ``slo_ticks`` is the per-request deadline in block-ticks (the paper's
    per-task deadline, §I); ``windows`` × ``window_ticks`` is the horizon.
    Θ control and re-allocation can be frozen independently — the
    ``frozen-Θ`` baseline of ``BENCH_serving.json`` is ``adapt_theta=False,
    reallocate=False``.
    """

    batching: BatchingConfig
    windows: int = 8                 # control windows
    window_ticks: int = 64           # block-ticks per window
    slo_ticks: float = 30.0          # deadline = arrival + slo_ticks
    target: float = 0.95             # attainment target for Θ control
    margin: float = 0.02             # controller hysteresis half-width
    theta_step: float = 0.1          # multiplicative Θ step
    theta_lo: float = 0.01
    theta_hi: float = 0.5
    adapt_theta: bool = True         # drive Θ from window attainment
    reallocate: bool = True          # between-window ACA re-allocation
    drain: bool = True               # finish the backlog after the horizon
    drain_max_ticks: int = 100_000

    def __post_init__(self):
        if self.windows < 1 or self.window_ticks < 1:
            raise ValueError("windows and window_ticks must be >= 1")
        if self.slo_ticks <= 0:
            raise ValueError("slo_ticks must be > 0")


class WindowReport(NamedTuple):
    """One control window as the session saw it."""

    window: int
    theta: float              # Θ in force *during* this window
    stats: SLOStats           # idle-window safe
    arrivals: int
    hits: int                 # cache-resolved among requests admitted
    admitted: int
    reallocated: bool
    degraded: bool = False    # served from a stale/absent table (sync fault)


class SessionResult(NamedTuple):
    """The live session's outcome — no metric replay involved.

    ``ticks`` is the lookup-adjusted busy-tick bill (block-batch executions
    actually run, idle ticks excluded); ``throughput`` is served requests
    per adjusted tick, the number load-level comparisons divide.
    ``exit_blocks`` holds every admitted request's resolved block count in
    admission order — feeding it to :func:`repro.serving.batching.simulate`
    reproduces the session's tick bill exactly on a backlogged trace (the
    parity test).
    """

    stats: SLOStats
    windows: list
    ticks: float
    served: int
    shed: int
    arrivals: int
    hit_ratio: float          # of admitted requests
    accuracy: float           # of served requests with known labels
    throughput: float
    theta_trace: list
    exit_blocks: np.ndarray


class ServingSession:
    """One client's online serving loop over a live CoCa cluster.

    ``cluster`` — a bootstrapped :class:`~repro.core.engine.CocaCluster`
    whose policy cuts the serving table (any ``AllocationPolicy``).
    ``workload`` — the open-loop request stream.  ``tap_fn(window, labels)``
    supplies the semantic taps and full-model logits for a batch of
    admitted requests — synthetic taps in the launcher, a real backbone's
    taps in ``examples/serve_stream.py``.  ``use_cache=False`` runs the
    same loop with the lookup disabled (every request pays all blocks) —
    the live no-cache baseline.

    Faults: with ``faults=`` (a :class:`repro.distributed.faults.FaultSpec`)
    every window-boundary table download runs through the spec's download
    matrix and outage windows, keyed by **window index** in place of the
    engine's round index.  ``hardened=True`` retries a failed transfer
    under ``retry``'s budget and otherwise serves the window from the last
    good table (staleness-counted, cache-off past ``stale_limit``) while
    the Θ controller **holds**
    (:meth:`~repro.serving.scheduler.ThetaController.hold`) — a
    fault-induced attainment dip says nothing about Θ.  ``hardened=False``
    is the naive contrast: one attempt, a dropped table serves full-depth,
    a corrupt/truncated one is used as delivered, and Θ reacts to the dip
    it caused.  An empty spec is discarded outright, so the zero-fault
    session is the pre-fault code path bit-for-bit.
    """

    def __init__(self, cluster, cfg: ServeLoopConfig,
                 workload: RequestStream, tap_fn: ServeTapFn, *,
                 use_cache: bool = True, client: int = 0,
                 faults=None, retry=None, hardened: bool = True,
                 stale_limit: int = 4):
        if workload.num_classes != cluster.sim.cache.num_classes:
            raise ValueError(
                f"workload has {workload.num_classes} classes, cluster cache "
                f"has {cluster.sim.cache.num_classes}")
        self.cluster = cluster
        self.cfg = cfg
        self.workload = workload
        self.tap_fn = tap_fn
        self.use_cache = use_cache
        self.client = client
        self._faults = None
        if faults is not None and not faults.empty:
            from repro.distributed.faults import RetryPolicy
            self._faults = faults
            self.retry = retry if retry is not None else RetryPolicy()
        self.hardened = hardened
        self.stale_limit = stale_limit
        self._good_table = None      # last successfully synced table
        self._stale = 0              # windows since a good sync
        I = cluster.sim.cache.num_classes
        # request-stream recency: tau_i = admitted requests since class i
        # was last observed (the engine's Eq.-10 unit, fed back at each
        # window boundary so ACA tracks the *served* distribution)
        self._last_seen = np.full(I, -1, np.int64)
        self._seen = 0

    # ----------------------------------------------------------------- utils
    def _estimated_blocks(self) -> float:
        """Cold-start admission cost estimate: expected blocks under the
        server's profiled first-hit CDF R (full depth without a cache).
        Once windows complete, the estimate tracks the *observed* resolved
        block counts instead (EWMA at each window boundary) — a static
        estimate goes stale the moment the Θ controller moves, and a stale
        underestimate admits doomed requests the shedding valve should have
        dropped."""
        nb = self.cfg.batching.num_blocks
        if not self.use_cache:
            return float(nb)
        r = np.asarray(self.cluster.r_est, float)
        first = np.diff(np.concatenate([[0.0], np.clip(r, 0.0, 1.0)]))
        first = np.clip(first, 0.0, None)
        blocks = np.arange(1, len(r) + 1, dtype=float)
        exp = float((first * blocks).sum() + (1.0 - min(r[-1], 1.0)) * nb)
        return float(np.clip(exp, 1.0, nb))

    def _observe(self, labels: np.ndarray) -> None:
        for lab in labels:
            self._last_seen[int(lab)] = self._seen
            self._seen += 1

    def _tau(self) -> np.ndarray:
        # never-requested classes are maximally stale (Eq. 10 scores LOW tau
        # as hot); at cold start (_seen == 0) this is all-zeros, matching
        # the engine's fresh-client convention
        tau = np.where(self._last_seen < 0, self._seen,
                       self._seen - 1 - self._last_seen)
        return tau.astype(np.int32)

    def _window_table(self, w: int):
        """The serving table for window ``w``, resolved through the fault
        spec (the identity when none is armed): ``(table, degraded)``.

        The serving loop's clock is block-ticks, so the retry budget is
        honoured in *wall seconds that never hit the tick bill* — the
        window boundary is between ticks; what the budget still decides is
        how many redraws a hardened client gets before giving up.
        """
        if not self.use_cache:
            return None, False

        def cut():
            return self.cluster.serving_table(
                client=self.client, tau=self._tau(), round_index=w)

        if self._faults is None:
            return cut(), False
        from repro.distributed.faults import (_DOM_CORRUPT_DOWN, _DOM_JITTER,
                                              corrupt_table, truncate_table)
        spec = self._faults
        down = spec.server_down(w)
        fault = "drop" if down else spec.draw_download(w, self.client)
        if fault == "ok":
            table = cut()
            self._good_table, self._stale = table, 0
            return table, False
        if self.hardened:
            jit_rng = spec.rng(_DOM_JITTER, w, self.client, 2)
            spent = 0.0
            for attempt in range(self.retry.max_retries):
                wait = self.retry.backoff(attempt, jit_rng)
                if spent + wait > self.retry.timeout:
                    break
                spent += wait
                redraw = ("drop" if down else
                          spec.draw_download(w, self.client,
                                             attempt=attempt + 1))
                if redraw == "ok":
                    table = cut()
                    self._good_table, self._stale = table, 0
                    return table, False
            self._stale += 1
            if (self._good_table is not None
                    and self._stale <= self.stale_limit):
                return self._good_table, True        # bounded-stale table
            return None, True                        # cache-off
        # naive: one attempt, serve whatever the wire delivered
        self._stale += 1
        if fault == "corrupt":
            return corrupt_table(
                cut(), spec.rng(_DOM_CORRUPT_DOWN, w, self.client)), True
        if fault == "partial":
            return truncate_table(cut(), spec.partial_frac), True
        return None, True                            # dropped download

    def _classify(self, window: int, labels: np.ndarray,
                  table: CacheTable | None):
        """The per-tick batched classification: real taps, real fused
        lookup on the live table.  Returns (blocks, hit, pred)."""
        nb = self.cfg.batching.num_blocks
        sems, logits = self.tap_fn(window, labels)
        model_pred = np.argmax(np.asarray(logits), axis=1).astype(np.int32)
        if not (self.use_cache and table is not None):
            return (np.full(len(labels), nb, np.int64),
                    np.zeros(len(labels), bool), model_pred)
        n = len(labels)
        sems = jnp.asarray(sems)
        pad = self.cfg.batching.max_slots - n
        if pad > 0:                      # fixed shape -> one compiled trace
            sems = jnp.concatenate(
                [sems, jnp.zeros((pad,) + sems.shape[1:], sems.dtype)])
        look = _batched_lookup(table, sems, self.cluster.sim.cache)
        hit = np.asarray(look.hit)[:n]
        exit_layer = np.asarray(look.exit_layer)[:n]
        blocks = np.where(hit, np.minimum(exit_layer + 1, nb), nb)
        pred = np.where(hit, np.asarray(look.pred)[:n], model_pred)
        return blocks.astype(np.int64), hit, pred.astype(np.int32)

    # ------------------------------------------------------------------ run
    def run(self) -> SessionResult:
        cfg = self.cfg
        sched = EDFScheduler(max_slots=cfg.batching.max_slots)
        ctl = ThetaController(
            theta=float(self.cluster.sim.cache.theta), target=cfg.target,
            margin=cfg.margin, step=cfg.theta_step,
            lo=cfg.theta_lo, hi=cfg.theta_hi)
        table, degraded_now = self._window_table(0)
        est_f = self._estimated_blocks()
        est = int(np.ceil(est_f))
        labels_by_rid: dict[int, int] = {}
        hit_by_rid: dict[int, bool] = {}
        pred_by_rid: dict[int, int] = {}
        exit_blocks: list[int] = []
        reports: list[WindowReport] = []
        theta_trace: list[float] = []
        correct = served_labeled = 0
        rid = 0
        admitted_total = hits_total = arrivals_total = 0

        def tick_body(window: int) -> None:
            nonlocal admitted_total, hits_total, correct, served_labeled
            placed = sched.admit()
            if placed:
                labs = np.asarray(
                    [labels_by_rid[r.rid] for _, r in placed], np.int32)
                blocks, hit, pred = self._classify(window, labs, table)
                for (slot, req), b, h, p in zip(placed, blocks, hit, pred):
                    sched.resolve(slot, int(b))
                    hit_by_rid[req.rid] = bool(h)
                    pred_by_rid[req.rid] = int(p)
                    exit_blocks.append(int(b))
                self._observe(labs)
                admitted_total += len(placed)
                hits_total += int(hit.sum())
            for req, _lat, _missed in sched.advance():
                lab = labels_by_rid[req.rid]
                served_labeled += 1
                correct += int(pred_by_rid[req.rid] == lab)

        for w in range(cfg.windows):
            theta_trace.append(float(self.cluster.sim.cache.theta))
            counts, labels = self.workload.window(w, cfg.window_ticks)
            arrivals_total += int(counts.sum())
            offsets = np.concatenate([[0], np.cumsum(counts)])
            admitted_w0, hits_w0 = admitted_total, hits_total
            blocks_w0 = len(exit_blocks)
            sched.begin_window()
            for t in range(cfg.window_ticks):
                for lab in labels[offsets[t]:offsets[t + 1]]:
                    labels_by_rid[rid] = int(lab)
                    sched.submit(Request(
                        rid=rid, arrival=sched.tick, blocks_needed=est,
                        deadline=sched.tick + cfg.slo_ticks))
                    rid += 1
                tick_body(w)
            stats = sched.window_stats()
            realloc = False
            # refresh the admission estimate from what this window's
            # lookups actually resolved (tracks the Θ controller)
            window_blocks = exit_blocks[blocks_w0:]
            if window_blocks:
                est_f = 0.5 * est_f + 0.5 * float(np.mean(window_blocks))
                est = int(np.ceil(est_f))
            # close the loop: attainment -> Θ, observed recency -> ACA.
            # A degraded window's dip is a sync fault, not a Θ signal —
            # the hardened session holds AIMD instead of chasing it.
            if cfg.adapt_theta and stats.served + stats.shed > 0:
                if degraded_now and self.hardened and self._faults is not None:
                    ctl.hold()
                else:
                    self.cluster.set_theta(ctl.update(stats.attainment))
            was_degraded = degraded_now
            if cfg.reallocate and self.use_cache:
                table, degraded_now = self._window_table(w + 1)
                realloc = not degraded_now
            reports.append(WindowReport(
                window=w, theta=theta_trace[-1], stats=stats,
                arrivals=int(counts.sum()), hits=hits_total - hits_w0,
                admitted=admitted_total - admitted_w0, reallocated=realloc,
                degraded=was_degraded))

        if cfg.drain:
            t = 0
            last_w = cfg.windows - 1
            while ((sched.queue or any(s is not None for s in sched.slots))
                   and t < cfg.drain_max_ticks):
                tick_body(last_w)
                t += 1

        overhead = (1 + cfg.batching.lookup_tick_fraction
                    if self.use_cache else 1.0)
        ticks = sched.busy_ticks * overhead
        return SessionResult(
            stats=sched.stats(), windows=reports, ticks=ticks,
            served=sched.served, shed=sched.shed, arrivals=arrivals_total,
            hit_ratio=hits_total / max(admitted_total, 1),
            accuracy=correct / max(served_labeled, 1),
            throughput=sched.served / max(ticks, 1e-9),
            theta_trace=theta_trace,
            exit_blocks=np.asarray(exit_blocks, np.int64))


def throughput_gain(cached: SessionResult, nocache: SessionResult) -> float:
    """Live throughput multiple: served-per-adjusted-tick ratio between a
    cached session and its no-cache twin on the same workload.  Idle-safe:
    two idle sessions gain exactly 1.0."""
    if cached.served == 0 and nocache.served == 0:
        return 1.0
    return cached.throughput / max(nocache.throughput, 1e-9)
