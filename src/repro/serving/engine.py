"""Compiled serving steps with first-class CoCa semantic caching.

This module owns the *data plane* of the serving stack: the pjit-compiled
model steps and the table plumbing that puts the paper's Eq. (1)/(2) lookup
inside them.  ``make_prefill_step`` / ``make_decode_step`` return
(fn, in_shardings, out_shardings) — the exact artifacts the multi-pod
dry-run lowers.  When the architecture has taps (``cfg.tap_every > 0``) the
step consumes a :class:`~repro.core.semantic_cache.CacheTable` (hot-spot
entries allocated by the CoCa server) and emits the Eq. (1)/(2) hit decision
alongside logits: on a hit the request is *resolved* — the orchestration
layer retires its slot and refills it, which is how the paper's early-exit
latency win materialises under batched SPMD execution.  The replay-form
cost model for that refill discipline is :mod:`repro.serving.batching`; the
online loop that drives admission, lookup and Θ control around these steps
is :mod:`repro.serving.loop` (see docs/serving.md).

``allocate_serving_table`` cuts a single client's table from a live
:class:`~repro.core.server.ServerState` with any engine
``AllocationPolicy`` — the standalone-server twin of
:meth:`CocaCluster.serving_table
<repro.core.engine.CocaCluster.serving_table>`, which the online loop uses
for its between-window re-allocation.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.semantic_cache import (CacheConfig, CacheTable,
                                       allocate_subtable, lookup_all_layers)
from repro.distributed.sharding import (SERVE_POLICY, ShardingPolicy,
                                        activation_sharding, batch_specs,
                                        cache_partition, make_param_shardings,
                                        to_named)
from repro.models.config import ModelConfig
from repro.models.transformer import Caches, decode_step, prefill


def coca_cache_config(cfg: ModelConfig, theta: float = 0.10,
                      alpha: float = 0.5) -> CacheConfig:
    return CacheConfig(num_classes=cfg.num_classes,
                       num_layers=len(cfg.tap_layers()),
                       sem_dim=cfg.sem_dim, alpha=alpha, theta=theta)


def allocate_serving_table(server, policy, cache_cfg: CacheConfig,
                           cost_model, *, mem_budget: float,
                           tau: np.ndarray | None = None,
                           round_frames: int = 300, round_index: int = 0,
                           client_index: int = 0) -> CacheTable:
    """Cut one client's serving :class:`CacheTable` from a live CoCa server
    with any :class:`~repro.core.engine.AllocationPolicy` — the serving path
    shares the engine's allocation machinery instead of carrying its own.

    ``server`` — a :class:`~repro.core.server.ServerState` (e.g. from
    ``CocaCluster.bootstrap``); ``tau`` — the client's recency vector
    (cold start = zeros).  The returned table plugs straight into
    ``make_prefill_step`` / ``make_decode_step``.
    """
    from repro.core.engine import AllocationContext
    I = cache_cfg.num_classes
    ctx = AllocationContext(
        round_index=round_index, client_index=client_index,
        phi_global=np.asarray(jax.device_get(server.phi_global)),
        tau=(np.zeros(I, np.int32) if tau is None else np.asarray(tau)),
        r_est=np.asarray(jax.device_get(server.r_est)),
        upsilon=np.asarray(jax.device_get(server.upsilon)),
        entry_sizes=cost_model.entry_sizes(), mem_budget=mem_budget,
        round_frames=round_frames)
    return allocate_subtable(server.entries, jnp.asarray(policy.allocate(ctx)),
                             entry_dtype=cache_cfg.entry_dtype)


def empty_serving_table(cfg: ModelConfig) -> CacheTable:
    c = coca_cache_config(cfg)
    return CacheTable(
        entries=jnp.zeros((c.num_layers, c.num_classes, c.sem_dim), jnp.float32),
        class_mask=jnp.zeros((c.num_classes,), bool),
        layer_mask=jnp.zeros((c.num_layers,), bool))


class CocaOut(NamedTuple):
    hit: jax.Array          # (B,) request resolved by the semantic cache
    pred: jax.Array         # (B,) class on hit
    exit_layer: jax.Array   # (B,) first hitting tap (== n_taps: none)
    scores: jax.Array       # (B, n_taps)


def _coca_lookup(cfg: ModelConfig, taps, table: CacheTable) -> CocaOut:
    c = coca_cache_config(cfg)
    look = lookup_all_layers(table, taps, c)
    return CocaOut(hit=look.hit, pred=look.pred,
                   exit_layer=look.exit_layer, scores=look.scores)


def make_prefill_step(cfg: ModelConfig, mesh: Mesh,
                      policy: ShardingPolicy = SERVE_POLICY,
                      max_len: int | None = None,
                      global_batch: int | None = None):
    has_taps = len(cfg.tap_layers()) > 0

    def prefill_step(params, batch, table: CacheTable | None = None):
        with activation_sharding(mesh, policy, "serve", global_batch):
            logits, caches, taps, cls = prefill(params, batch, cfg, max_len)
            out = {"logits": logits, "caches": caches}
            if cls is not None:
                out["cls_logits"] = cls
            if has_taps and table is not None:
                out["coca"] = _coca_lookup(cfg, taps, table)
            return out

    abstract_params = jax.eval_shape(
        lambda k: __import__("repro.models", fromlist=["init_params"]
                             ).init_params(k, cfg),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    p_shard = make_param_shardings(cfg, mesh, policy, abstract_params)
    b_shard = to_named(batch_specs(cfg, mesh, "prefill", global_batch), mesh)
    repl = NamedSharding(mesh, P())
    t_shard = CacheTable(entries=repl, class_mask=repl, layer_mask=repl)
    return prefill_step, (p_shard, b_shard, t_shard)


def make_decode_step(cfg: ModelConfig, mesh: Mesh,
                     policy: ShardingPolicy = SERVE_POLICY,
                     global_batch: int | None = None):
    """serve_step: one new token for every live slot, CoCa lookup included."""
    has_taps = len(cfg.tap_layers()) > 0

    def serve_step(params, tokens, caches: Caches,
                   table: CacheTable | None = None):
        with activation_sharding(mesh, policy, "serve", global_batch):
            logits, new_caches, taps, cls = decode_step(params, tokens,
                                                        caches, cfg)
            next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            out = {"logits": logits, "next_token": next_tok,
                   "caches": new_caches}
            if cls is not None:
                out["cls_logits"] = cls
            if has_taps and table is not None:
                out["coca"] = _coca_lookup(cfg, taps, table)
            return out

    abstract_params = jax.eval_shape(
        lambda k: __import__("repro.models", fromlist=["init_params"]
                             ).init_params(k, cfg),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    p_shard = make_param_shardings(cfg, mesh, policy, abstract_params)
    from repro.distributed.sharding import dp_axes_for
    if global_batch is not None:
        dp = dp_axes_for(global_batch, mesh)
    else:
        dp = tuple(a for a in mesh.axis_names if a in ("pod", "data")) or None
    tok_shard = NamedSharding(mesh, P(dp, None))
    c_shard = to_named(cache_partition(cfg, mesh, policy, global_batch), mesh)
    repl = NamedSharding(mesh, P())
    t_shard = CacheTable(entries=repl, class_mask=repl, layer_mask=repl)
    return serve_step, (p_shard, tok_shard, c_shard, t_shard)
