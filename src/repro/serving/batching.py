"""Continuous-batching cost model: early-exit slot refill in block-ticks.

Under batched SPMD execution a single lane cannot stop early — the batch
marches through every block together.  The throughput win of the paper's
early exit therefore materialises at the *scheduler*: a request whose
semantic-cache lookup hits at tap j is resolved, its slot retires after
block j and is refilled by the next queued request.  Cost accounting per
"block-tick": every tick advances all live slots one block at a cost of one
block-batch; a request that exits at tap j consumed j+1 ticks instead of L.

This module owns that accounting in **replay** form: ``simulate`` is a
discrete-time simulator over per-request exit layers (the canonical
:class:`~repro.core.metrics.RoundMetrics` record via ``simulate_metrics``,
or a real model's taps) that reports the throughput multiple vs. a no-cache
engine — the serving-side reproduction of the paper's Table II latency
wins.  The *online* counterpart — open-loop arrivals, EDF admission, live
fused lookups, Θ control — lives in :mod:`repro.serving.loop` and shares
this module's :class:`BatchingConfig` and tick accounting, which is what
makes the closed-loop session replay-parity-testable
(``tests/test_serving.py``).

Both entry points are idle-safe: an empty request set (a zero-request
window in the online loop) returns well-defined zero-work stats with a
neutral throughput gain of 1.0.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class BatchingConfig:
    num_blocks: int              # L+1 model blocks
    max_slots: int = 32          # batch lanes
    lookup_tick_fraction: float = 0.05   # cache-lookup cost per tap, in ticks


class ServingStats(NamedTuple):
    ticks: float                 # block-batch executions
    baseline_ticks: float        # no-cache engine for the same request set
    throughput_gain: float       # baseline / actual
    mean_slot_occupancy: float
    requests: int


def simulate_metrics(metrics, cfg: BatchingConfig) -> ServingStats:
    """Drive :func:`simulate` from a canonical
    :class:`~repro.core.metrics.RoundMetrics` record (or a list of them) —
    the engine's per-frame exit layers become slot-occupancy ticks."""
    from repro.core.metrics import RoundMetrics
    records = [metrics] if isinstance(metrics, RoundMetrics) else list(metrics)
    if not records:
        return simulate(np.zeros(0, np.int64), cfg)
    blocks = np.concatenate([m.exit_blocks(cfg.num_blocks) for m in records])
    return simulate(blocks, cfg)


def simulate(exit_blocks: np.ndarray, cfg: BatchingConfig) -> ServingStats:
    """``exit_blocks`` — (N,) blocks each request must execute (exit layer+1;
    no-hit requests carry ``num_blocks``).  An empty request set (an idle
    window) returns zero-work stats with a neutral gain of 1.0."""
    n = len(exit_blocks)
    if n == 0:
        return ServingStats(ticks=0.0, baseline_ticks=0.0,
                            throughput_gain=1.0, mean_slot_occupancy=0.0,
                            requests=0)
    queue = list(exit_blocks)
    slots = np.zeros(cfg.max_slots)          # remaining blocks per slot
    live = np.zeros(cfg.max_slots, bool)
    ticks = 0.0
    occupancy = 0.0
    done = 0
    while done < n:
        # refill free slots
        for i in range(cfg.max_slots):
            if not live[i] and queue:
                slots[i] = queue.pop(0)
                live[i] = True
        ticks += 1.0
        occupancy += live.mean()
        slots[live] -= 1
        finished = live & (slots <= 0)
        done += int(finished.sum())
        live &= ~finished
    baseline = n * cfg.num_blocks / cfg.max_slots
    # lookup overhead: each tick all live slots also pay the tap lookup
    ticks *= (1 + cfg.lookup_tick_fraction)
    return ServingStats(ticks=ticks, baseline_ticks=baseline,
                        throughput_gain=baseline / max(ticks, 1e-9),
                        mean_slot_occupancy=occupancy / max(ticks, 1e-9),
                        requests=n)
