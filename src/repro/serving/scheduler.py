"""SLO-aware request scheduler for CoCa serving.

The paper's framing is SLO compliance ("a 30 % latency reduction target",
§Abstract; per-task deadlines, §I).  This scheduler closes that loop above
the continuous-batching engine:

  * requests carry deadlines; admission is earliest-deadline-first with a
    load-shedding valve (drop requests that cannot meet their deadline even
    if scheduled immediately — serving a doomed request wastes slots);
  * per-window SLO attainment, p50/p95 latency and cache-hit statistics are
    tracked and exposed to the CoCa server, which can tighten/relax Θ between
    rounds (hit ratio ↑ when the SLO is at risk, accuracy ↑ when there is
    slack) — the dynamic analogue of the paper's static Θ-per-SLO table
    (§VI.D).

Pure-python control plane (decisions happen between compiled steps); the
simulator in serving/batching.py provides the execution model.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import NamedTuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Request:
    rid: int
    arrival: float           # tick of arrival
    blocks_needed: int       # exit block under the current cache (oracle/est)
    deadline: float          # absolute tick deadline


class SLOStats(NamedTuple):
    served: int
    shed: int
    missed: int
    attainment: float
    p50: float
    p95: float


@dataclasses.dataclass
class ThetaController:
    """Between-round Θ adjustment from SLO attainment (bang-bang + hysteresis).

    attainment < target - margin  -> lower Θ (more early exits, faster)
    attainment > target + margin  -> raise Θ (spend slack on accuracy)

    This is also the engine's per-round theta hook:
    ``CocaCluster(theta_policy=SLOTheta(...))`` (repro.core.engine) computes
    attainment from each round's canonical metrics and drives this
    controller between ``step()`` calls.
    """

    theta: float
    target: float = 0.95
    margin: float = 0.02
    step: float = 0.1          # multiplicative
    lo: float = 0.01
    hi: float = 0.5

    def update(self, attainment: float) -> float:
        if attainment < self.target - self.margin:
            self.theta = max(self.lo, self.theta * (1 - self.step))
        elif attainment > self.target + self.margin:
            self.theta = min(self.hi, self.theta * (1 + self.step))
        return self.theta


class EDFScheduler:
    """Earliest-deadline-first with load shedding over batched block-ticks."""

    def __init__(self, max_slots: int):
        self.max_slots = max_slots
        self.queue: list[tuple[float, int, Request]] = []
        self.slots: list[tuple[Request, int, float] | None] = \
            [None] * max_slots
        self.tick = 0.0
        self.latencies: list[float] = []
        self.served = self.shed = self.missed = 0

    def submit(self, req: Request) -> None:
        heapq.heappush(self.queue, (req.deadline, req.rid, req))

    def _admit(self) -> None:
        for i in range(self.max_slots):
            if self.slots[i] is not None:
                continue
            while self.queue:
                _, _, req = heapq.heappop(self.queue)
                if self.tick + req.blocks_needed > req.deadline:
                    self.shed += 1          # cannot make it: shed, don't burn
                    continue
                self.slots[i] = (req, req.blocks_needed, self.tick)
                break

    def run_tick(self) -> None:
        self._admit()
        self.tick += 1.0
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            req, remaining, start = s
            remaining -= 1
            if remaining <= 0:
                lat = self.tick - req.arrival
                self.latencies.append(lat)
                self.served += 1
                if self.tick > req.deadline:
                    self.missed += 1
                self.slots[i] = None
            else:
                self.slots[i] = (req, remaining, start)

    def drain(self, max_ticks: int = 100_000) -> None:
        t = 0
        while (self.queue or any(self.slots)) and t < max_ticks:
            self.run_tick()
            t += 1

    def stats(self) -> SLOStats:
        lat = np.asarray(self.latencies) if self.latencies else np.zeros(1)
        total = self.served + self.shed
        ok = self.served - self.missed
        return SLOStats(
            served=self.served, shed=self.shed, missed=self.missed,
            attainment=ok / max(total, 1),
            p50=float(np.percentile(lat, 50)),
            p95=float(np.percentile(lat, 95)))
