"""SLO-aware admission control: EDF + load shedding + the Θ controller.

This module owns the serving *control plane* — which request runs next, which
request is hopeless, and how the cache threshold Θ should move in response to
observed SLO attainment.  It reproduces the paper's SLO framing (per-task
deadlines, §I; the Θ-per-SLO calibration of §VI.D) as three pieces:

* :class:`Request` / :class:`EDFScheduler` — requests carry absolute
  deadlines; admission is earliest-deadline-first with a load-shedding valve
  (a request that cannot meet its deadline even if scheduled immediately is
  dropped rather than allowed to burn a batch slot).  Admission
  (:meth:`EDFScheduler.admit`) is decoupled from execution
  (:meth:`EDFScheduler.advance`) so a driver can *resolve* each admitted
  request's true block count from a live cache lookup — the online serving
  loop (:mod:`repro.serving.loop`) does exactly that; :meth:`run_tick` fuses
  the two for the classic oracle-replay mode.
* :class:`SLOStats` — per-window attainment / p50 / p95, well-defined for the
  idle (zero-request) window.
* :class:`ThetaController` — bang-bang Θ adjustment with hysteresis:
  attainment below target lowers Θ (more early exits, faster), slack above
  target raises it (spend the headroom on accuracy) — the dynamic analogue of
  the paper's static Θ-per-SLO table.  It backs both the serving loop's
  per-window control and the engine's per-round ``theta_policy`` hook
  (:class:`repro.core.engine.SLOTheta`).

Everything here is pure-Python control flow: decisions happen between
compiled steps, never inside them.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import NamedTuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Request:
    rid: int
    arrival: float           # tick of arrival
    blocks_needed: int       # exit block estimate at admission (resolvable)
    deadline: float          # absolute tick deadline


class SLOStats(NamedTuple):
    """One window's SLO accounting.  ``attainment`` counts shed requests as
    misses (a dropped request did not meet its deadline).  An idle window
    (no requests finished or shed) reports vacuous attainment 1.0 and zero
    percentiles — controllers should treat it as "no evidence", not as an
    SLO violation."""

    served: int
    shed: int
    missed: int
    attainment: float
    p50: float
    p95: float

    @classmethod
    def from_counts(cls, latencies, served: int, shed: int,
                    missed: int) -> "SLOStats":
        total = served + shed
        if total == 0:
            return cls(served=0, shed=shed, missed=0,
                       attainment=1.0, p50=0.0, p95=0.0)
        lat = (np.asarray(latencies, float) if len(latencies)
               else np.zeros(1))
        return cls(served=served, shed=shed, missed=missed,
                   attainment=(served - missed) / total,
                   p50=float(np.percentile(lat, 50)),
                   p95=float(np.percentile(lat, 95)))


@dataclasses.dataclass
class ThetaController:
    """Between-window Θ adjustment from SLO attainment (bang-bang + hysteresis).

    attainment < target - margin  -> lower Θ (more early exits, faster)
    attainment > target + margin  -> raise Θ (spend slack on accuracy)
    inside the deadband           -> hold (the hysteresis that stops
                                    oscillation at the boundary)

    The steps are asymmetric (AIMD-style): the upward step is a fraction of
    the downward one (``step_up``, default ``0.3 * step``), because the two
    directions are not symmetric risks — raising Θ explores toward the
    capacity cliff while a violation means a queue backlog is already
    compounding, so recovery must outpace exploration or one overshoot
    poisons several windows of deadlines.

    Drives the online serving loop's per-window control
    (:class:`repro.serving.loop.ServingSession`) and the engine's per-round
    theta hook: ``CocaCluster(theta_policy=SLOTheta(...))``
    (:mod:`repro.core.engine`) computes attainment from each round's
    canonical metrics and feeds it here between ``step()`` calls.
    """

    theta: float
    target: float = 0.95
    margin: float = 0.02
    step: float = 0.1                  # multiplicative, downward
    lo: float = 0.01
    hi: float = 0.5
    step_up: float | None = None       # upward step; None = 0.3 * step

    def update(self, attainment: float) -> float:
        up = self.step_up if self.step_up is not None else 0.3 * self.step
        if attainment < self.target - self.margin:
            self.theta = max(self.lo, self.theta * (1 - self.step))
        elif attainment > self.target + self.margin:
            self.theta = min(self.hi, self.theta * (1 + up))
        return self.theta

    def hold(self) -> float:
        """Freeze Θ for one window — the degraded-mode interlock.

        A window served from a stale or absent table (a sync fault, not a
        load change — :mod:`repro.distributed.faults`) produces an
        attainment dip that carries *no information about Θ*: reacting to
        it drives Θ to the floor, and the post-recovery windows then pay
        the AIMD climb all the way back.  The serving loop calls ``hold()``
        instead of :meth:`update` while degraded, so control resumes from
        where the fault found it."""
        return self.theta


class EDFScheduler:
    """Earliest-deadline-first admission with load shedding over block-ticks.

    Two driving modes share the same state:

    * **oracle replay** — :meth:`run_tick` / :meth:`drain`: each request's
      ``blocks_needed`` is trusted as its true cost (per-request exit layers
      produced offline).
    * **live** — the serving loop calls :meth:`admit` (EDF pop + shedding,
      placement into free slots at the *estimated* cost), then
      :meth:`resolve` with each admitted request's true block count from the
      batched cache lookup, then :meth:`advance` to burn one block-tick.
    """

    def __init__(self, max_slots: int):
        self.max_slots = max_slots
        self.queue: list[tuple[float, int, Request]] = []
        self.slots: list[tuple[Request, float, float] | None] = \
            [None] * max_slots
        self.tick = 0.0
        self.busy_ticks = 0.0            # ticks with >= 1 live slot
        self.latencies: list[float] = []
        self.served = self.shed = self.missed = 0
        self._mark = (0, 0, 0, 0)        # window-start counter snapshot

    def submit(self, req: Request) -> None:
        heapq.heappush(self.queue, (req.deadline, req.rid, req))

    # ------------------------------------------------------------- admission
    def admit(self) -> list[tuple[int, Request]]:
        """Fill free slots EDF-first; shed requests that cannot meet their
        deadline even if started now (at their estimated cost).  Returns the
        newly placed ``(slot, request)`` pairs; each slot's remaining blocks
        start at the request's estimate until :meth:`resolve` overrides it."""
        placed = []
        for i in range(self.max_slots):
            if self.slots[i] is not None:
                continue
            while self.queue:
                _, _, req = heapq.heappop(self.queue)
                if self.tick + req.blocks_needed > req.deadline:
                    self.shed += 1          # cannot make it: shed, don't burn
                    continue
                self.slots[i] = (req, float(req.blocks_needed), self.tick)
                placed.append((i, req))
                break
        return placed

    def resolve(self, slot: int, blocks: float) -> None:
        """Replace a freshly admitted request's estimated cost with its true
        block count (the live lookup's verdict: exit layer + 1 on a hit, all
        blocks on a miss)."""
        occ = self.slots[slot]
        if occ is None:
            raise ValueError(f"resolve() on empty slot {slot}")
        req, _, start = occ
        self.slots[slot] = (req, max(float(blocks), 1.0), start)

    # ------------------------------------------------------------- execution
    def advance(self) -> list[tuple[Request, float, bool]]:
        """Burn one block-tick on every live slot; retire finished requests.
        Returns ``(request, latency, missed_deadline)`` per retirement."""
        live = any(s is not None for s in self.slots)
        self.tick += 1.0
        if live:
            self.busy_ticks += 1.0
        retired = []
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            req, remaining, start = s
            remaining -= 1
            if remaining <= 0:
                lat = self.tick - req.arrival
                self.latencies.append(lat)
                self.served += 1
                missed = self.tick > req.deadline
                if missed:
                    self.missed += 1
                retired.append((req, lat, missed))
                self.slots[i] = None
            else:
                self.slots[i] = (req, remaining, start)
        return retired

    def run_tick(self) -> None:
        """Oracle-replay tick: admit at trusted costs, then advance."""
        self.admit()
        self.advance()

    def drain(self, max_ticks: int = 100_000) -> None:
        t = 0
        while (self.queue or any(s is not None
                                 for s in self.slots)) and t < max_ticks:
            self.run_tick()
            t += 1

    # --------------------------------------------------------------- windows
    def begin_window(self) -> None:
        """Mark the current counters as the window start for
        :meth:`window_stats`."""
        self._mark = (self.served, self.shed, self.missed,
                      len(self.latencies))

    def window_stats(self) -> SLOStats:
        """SLO stats for the requests finished/shed since
        :meth:`begin_window` (idle-window safe)."""
        s0, d0, m0, l0 = self._mark
        return SLOStats.from_counts(self.latencies[l0:], self.served - s0,
                                    self.shed - d0, self.missed - m0)

    def stats(self) -> SLOStats:
        """Whole-session SLO stats (idle-session safe)."""
        return SLOStats.from_counts(self.latencies, self.served, self.shed,
                                    self.missed)
